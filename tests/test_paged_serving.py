"""Paged-KV serving subsystem: pool allocation, scheduler fairness
(FIFO / starvation-freedom), preemption, token-budget admission, the
int8pt per-tensor format, quantized paged decode, and the single
grouped-GEMM plan-cache signature per mixed-batch decode step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotune
from repro.models import model as model_lib
from repro.serving import (ContinuousBatchingScheduler, KVPagePool, Request,
                           ServingEngine)


def _cfg():
    cfg = get_config("gemma_2b").reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                               vocab=128, n_heads=2, n_kv_heads=1,
                               head_dim=32)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n, dtype=np.int32)
               for n in (5, 9, 13)]
    return cfg, params, prompts


# -- KVPagePool ---------------------------------------------------------------


def test_pool_growth_without_recompaction():
    pool = KVPagePool(num_pages=8, page_size=4)
    assert pool.free_pages == 7  # page 0 reserved (null page)
    assert pool.ensure(1, 5)     # 2 pages
    first = pool.pages_of(1)
    assert len(first) == 2 and 0 not in first
    assert pool.ensure(1, 12)    # grow to 3 pages
    assert pool.pages_of(1)[:2] == first  # existing ids never move
    assert pool.ensure(1, 12)    # idempotent
    assert len(pool.pages_of(1)) == 3


def test_pool_exhaustion_and_release():
    pool = KVPagePool(num_pages=5, page_size=4)
    assert pool.ensure(1, 8)          # 2 of 4 usable
    assert pool.ensure(2, 8)          # the other 2
    assert not pool.ensure(3, 4)      # dry: refused, nothing changed
    assert pool.pages_of(3) == []
    assert pool.release(1) == 2
    assert pool.ensure(3, 4)
    row = pool.table_row(3, max_pages=4)
    assert row[0] == pool.pages_of(3)[0] and (row[1:] == -1).all()
    assert (pool.table_row(None, 3) == -1).all()


# -- scheduler fairness -------------------------------------------------------


def test_admit_prefers_longest_waiting_after_preemption():
    """A preempted request keeps its arrival stamp and is re-admitted
    before requests submitted after it (FIFO fairness, not
    submission-list order)."""
    sched = ContinuousBatchingScheduler(slots=2, max_seq_len=32,
                                        page_size=4, num_pages=8)
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_tokens=4)
            for i in range(3)]
    e0, e1 = sched.submit(reqs[0]), sched.submit(reqs[1])
    s0 = sched.pop_admit(prefill_len=8)
    s1 = sched.pop_admit(prefill_len=8)
    assert s0[1].rid == 0 and s1[1].rid == 1
    # grow slot 0 until the pool forces eviction of the *youngest* (rid 1)
    evicted = sched.ensure_decode(s0[0], tokens=24)
    assert [e.rid for _, e in evicted] == [1]
    # a later request arrives while rid 1 waits; rid 0 then finishes
    sched.submit(reqs[2])
    sched.release(s0[0])
    got = sched.pop_admit(prefill_len=8)
    assert got is not None and got[1].rid == 1, \
        "preempted request must be re-admitted before younger arrivals"
    order = [rid for kind, rid in sched.events if kind == "admit"]
    assert order == [0, 1, 1]


def test_scheduler_token_budget_admission():
    sched = ContinuousBatchingScheduler(slots=4, max_seq_len=64,
                                        page_size=8, token_budget=40)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=np.zeros(4, np.int32),
                             max_tokens=4))
    assert sched.pop_admit(prefill_len=16) is not None  # commit 20
    assert sched.pop_admit(prefill_len=16) is not None  # commit 40
    assert sched.pop_admit(prefill_len=16) is None      # 60 > budget
    sched.release(0)
    assert sched.pop_admit(prefill_len=16) is not None


def test_starvation_freedom_under_repeated_preemption(setup):
    """Every request completes even when the pool is small enough to
    force evictions; the preempted request finishes before requests that
    arrived after it are admitted."""
    cfg, params, _ = setup
    rng = np.random.default_rng(2)
    engine = ServingEngine(params, cfg, slots=2, cache_len=64,
                           prefill_len=16, page_size=8, num_pages=7)
    n_req = 4
    for rid in range(n_req):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, 7, dtype=np.int32),
            max_tokens=12))
    outputs = engine.run(max_steps=500)
    assert len(outputs) == n_req
    assert all(len(v) == 12 for v in outputs.values())
    assert engine.sched.preemptions > 0, "pool was sized to force eviction"
    # fairness: once preempted, a request is re-admitted before any
    # younger first-time admission
    events = engine.sched.events
    for i, (kind, rid) in enumerate(events):
        if kind != "preempt":
            continue
        later_admits = [r for k, r in events[i:] if k == "admit"]
        first_subs = {r for k, r in events if k == "submit"}
        # the first later admit of a request submitted after `rid`
        # must come after `rid`'s own re-admit
        readmit = later_admits.index(rid)
        for j, r in enumerate(later_admits[:readmit]):
            assert r <= rid or r not in first_subs


def test_engine_cancels_head_that_can_never_fit(setup):
    """A head request the pool can never hold is cancelled with a
    structured ``capacity`` status (freeing the line behind it) instead
    of wedging the engine — requests that do fit still complete."""
    cfg, params, prompts = setup
    engine = ServingEngine(params, cfg, slots=1, cache_len=32,
                           prefill_len=16, page_size=4, num_pages=3)
    engine.submit(Request(rid=0, prompt=prompts[0], max_tokens=4))
    out = engine.run()
    assert out[0].status == "capacity" and list(out[0]) == []
    assert "never be admitted" in str(out[0].error)
    m = engine.metrics()
    assert m["cancelled_requests"] == 1
    assert m["free_pages"] == m["num_pages"] - 1  # nothing leaked


# -- int8pt format policy -----------------------------------------------------


def test_int8pt_policy_registered():
    from repro.core.formats import FORMATS, resolve_format
    fp = resolve_format("int8pt")
    assert fp.quantized and not fp.per_channel
    assert FORMATS["int8"].per_channel


def test_int8pt_gemm_parity_with_per_channel():
    """Per-tensor scales track per-channel (and fp32) closely on
    well-conditioned operands — the parity bound for the KV default."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    ref = np.asarray(a) @ np.asarray(b)
    out_pc = np.asarray(ops.mte_gemm(a, b, format_policy="int8"))
    out_pt = np.asarray(ops.mte_gemm(a, b, format_policy="int8pt"))
    span = np.abs(ref).max()
    assert np.max(np.abs(out_pc - ref)) / span < 0.05
    assert np.max(np.abs(out_pt - ref)) / span < 0.08
    # distinct cache keys: same shape under the two policies = two plans
    sigs = {s.fmt for s in autotune.plan_cache()._plans
            if s.m == 16 and s.n == 32 and s.k == 64}
    assert {"int8", "int8pt"} <= sigs


# -- paged decode through the engine ------------------------------------------


def _run_engine(params, cfg, prompts, **kw):
    engine = ServingEngine(params, cfg, slots=2, cache_len=64,
                           prefill_len=16, **kw)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_tokens=6))
    return engine, engine.run()


def test_quantized_kv_formats_parity(setup):
    """int8pt (per-tensor, the quantized KV default) stays close to the
    per-channel int8 KV path and to the unquantized baseline."""
    cfg, params, prompts = setup
    _, base = _run_engine(params, cfg, prompts)
    _, out_pc = _run_engine(params, cfg, prompts, kv_format="int8")
    _, out_pt = _run_engine(params, cfg, prompts, kv_format="int8pt")
    same_pc = sum(a == b for rid in base
                  for a, b in zip(base[rid], out_pc[rid]))
    same_pt = sum(a == b for rid in base
                  for a, b in zip(base[rid], out_pt[rid]))
    total = sum(len(v) for v in base.values())
    # greedy argmax is robust to int8 KV error on nearly all steps
    assert same_pc >= total - 2, (base, out_pc)
    assert same_pt >= total - 2, (base, out_pt)


def test_cache_quant_defaults_to_int8pt(setup):
    cfg, params, prompts = setup
    cfg_q = dataclasses.replace(cfg, cache_quant=True)
    engine, out = _run_engine(params, cfg_q, prompts[:2])
    assert engine.cfg.kv_cache_format == "int8pt"
    assert engine.cfg.cache_quant is False  # paged storage replaces it
    leaf = engine.cache["groups"][0]
    assert leaf["k_pages"].dtype == jnp.int8 and "k_scale" in leaf
    assert all(len(v) == 6 for v in out.values())


def test_mixed_batch_decode_issues_one_grouped_signature(setup):
    """Decode steps for a mixed batch must issue ONE grouped-GEMM
    plan-cache signature (G=3 q/k/v batching) instead of N GEMV
    launches — the acceptance criterion of the grouped decode path."""
    cfg, params, prompts = setup
    autotune.reset_cache()
    engine, out = _run_engine(params, cfg, prompts, grouped_qkv=True)
    assert all(len(v) == 6 for v in out.values())
    sigs = list(autotune.plan_cache()._plans)
    grouped = [s for s in sigs if s.group > 1]
    assert len(grouped) == 1, sigs
    (sig,) = grouped
    assert sig.group == 3            # q, k, v in one launch
    assert sig.m == engine.slots     # the whole mixed batch at once
    assert sig.k == cfg.d_model
    # and no per-projection GEMV signatures leaked through the ops layer
    assert not [s for s in sigs if s.group == 1 and s.m == engine.slots]
    # one solver call total: the signature is planned at trace time and
    # the compiled decode re-runs without re-entering the planner
    assert autotune.cache_stats().solver_calls == 1


def test_grouped_qkv_decode_matches_ungrouped_logits(setup):
    """The grouped projection is a layout change, not a numerics change:
    decode logits match the per-projection path closely."""
    cfg, params, prompts = setup
    cfg_g = dataclasses.replace(cfg, decode_qkv_grouped=True)
    tokens = jnp.asarray(np.asarray(prompts[0][:8])[None])
    _, cache1 = model_lib.prefill(params, {"tokens": tokens}, cfg,
                                  cache_len=16)
    cache2 = jax.tree.map(jnp.copy, cache1)
    batch = {"tokens": tokens[:, :1], "pos": jnp.int32(8)}
    d1, _ = model_lib.decode(params, batch, cache1, cfg)
    d2, _ = model_lib.decode(params, batch, cache2, cfg_g)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-4, atol=2e-4)


def test_paged_pallas_backend_close_to_xla(setup):
    """The page-table-indexed flash-decode kernel serves the same tokens
    as the XLA gather path on the pallas backend."""
    cfg, params, prompts = setup
    _, base = _run_engine(params, cfg, prompts[:2])
    cfg_p = dataclasses.replace(cfg, gemm_backend="pallas")
    _, out = _run_engine(params, cfg_p, prompts[:2], grouped_qkv=False)
    same = sum(a == b for rid in base for a, b in zip(base[rid], out[rid]))
    total = sum(len(v) for v in base.values())
    assert same >= total - 2, (base, out)


def test_engine_metrics_shape(setup):
    cfg, params, prompts = setup
    engine, _ = _run_engine(params, cfg, prompts)
    m = engine.metrics()
    assert m["completed_requests"] == 3
    assert 0.0 < m["batch_occupancy"] <= 1.0
    assert m["prefill_tokens"] == 3 * engine.prefill_len
    assert m["decode_tokens"] > 0
    assert m["free_pages"] == m["num_pages"] - 1  # all released at exit
