"""Observability layer (PR 9): continuous profiler + plan-quality audit,
SLO burn-rate monitors, Prometheus/health exposition — and the standing
contract that none of it perturbs serving outputs.

Covers: histogram reservoir bound, Prometheus round-trip for every
metric type, the calibration join counting grouped dispatches exactly
once, PlanCache.recalibrate/runner_up, SLO evaluation + burn windows,
health() schema validation, graph.program spans, kv.* per-step gauges,
and greedy bit-identity with the full observability stack on vs off."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotune, dispatch, perfmodel
from repro.graph import GraphBuilder, compile_graph
from repro.graph import fuse as fuse_mod
from repro.graph import ir as ir_mod
from repro.graph import schedule as sched_mod
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine
from repro.telemetry import export as export_mod
from repro.telemetry import gemm_account, tracing
from repro.telemetry.profiler import DispatchProfiler
from repro.telemetry.registry import (DEFAULT_MAX_SAMPLES, Histogram,
                                      MetricsRegistry, registry,
                                      reset_registry)
from repro.telemetry.slo import (Slo, SloMonitor, Window, default_slos)

RNG = np.random.default_rng(23)


@pytest.fixture(autouse=True)
def clean_telemetry():
    autotune.reset_cache()
    sched_mod.reset_programs()
    reset_registry()
    tracing.uninstall()
    gemm_account.uninstall()
    perfmodel.clear_calibration()
    yield
    tracing.uninstall()
    gemm_account.uninstall()
    autotune.reset_cache()
    sched_mod.reset_programs()
    reset_registry()
    perfmodel.clear_calibration()


def _arr(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def _cfg():
    cfg = get_config("gemma_2b").reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                               vocab=128, n_heads=2, n_kv_heads=1,
                               head_dim=32)


# -- histogram reservoir (satellite: bounded retained samples) ----------------


def test_histogram_reservoir_bounds_memory():
    h = Histogram("r.lat_s", edges=(0.5,), max_samples=64)
    for i in range(1000):
        h.observe(float(i))
    assert h.retained == 64                  # the bound holds
    assert h.count == 1000                   # exact count survives
    assert h.total == sum(range(1000))       # exact sum survives
    assert h.bucket_counts() == [(0.5, 1), (float("inf"), 1000)]
    # the reservoir is a uniform sample of [0, 1000): its median is a
    # sane estimate, not garbage pinned to one end
    assert 100.0 < h.percentile(50) < 900.0


def test_histogram_exact_below_cap_and_default_cap():
    h = Histogram("r.small_s", edges=(1.0,), max_samples=8)
    for v in (5.0, 1.0, 3.0):
        h.observe(v)
    assert h.retained == 3
    assert h.percentile(0) == 1.0 and h.percentile(100) == 5.0
    assert Histogram("r.dflt_s").max_samples == DEFAULT_MAX_SAMPLES
    with pytest.raises(ValueError):
        Histogram("r.bad_s", max_samples=0)
    # registry passes the cap through
    reg = MetricsRegistry()
    assert reg.histogram("x.h", max_samples=16).max_samples == 16


def test_histogram_reservoir_deterministic_per_name():
    def fill(name):
        h = Histogram(name, edges=(0.5,), max_samples=16)
        for i in range(200):
            h.observe(float(i))
        return list(h._samples)
    assert fill("a.h_s") == fill("a.h_s")    # seeded by name: reproducible


# -- prometheus exposition round-trip -----------------------------------------


def test_prometheus_round_trips_every_metric_type():
    reg = MetricsRegistry()
    reg.counter("serving.tokens_total").inc(41)
    reg.gauge("kv.free_pages").set(12.5)
    h = reg.histogram("serving.ttft_s", edges=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    text = export_mod.render_prometheus(reg)
    parsed = export_mod.parse_prometheus(text)
    c = parsed[export_mod.sanitize_metric_name("serving.tokens_total")]
    assert c["type"] == "counter" and c["value"] == 41
    g = parsed[export_mod.sanitize_metric_name("kv.free_pages")]
    assert g["type"] == "gauge" and g["value"] == 12.5
    hp = parsed[export_mod.sanitize_metric_name("serving.ttft_s")]
    assert hp["type"] == "histogram"
    assert hp["count"] == h.count
    assert hp["sum"] == pytest.approx(h.total)
    assert hp["buckets"] == [(e, c) for e, c in h.bucket_counts()]


def test_prometheus_name_sanitization():
    assert export_mod.sanitize_metric_name("a.b-c d") == "a_b_c_d"
    assert export_mod.sanitize_metric_name("9lives") == "_9lives"
    with pytest.raises(ValueError):
        export_mod.parse_prometheus("not a metric line at all!!")


# -- the calibration join -----------------------------------------------------


def test_calibration_join_counts_grouped_dispatch_once():
    """Three group-fused sibling GEMMs execute as ONE grouped launch:
    the calibration table must attribute ONE dispatch (kind=grouped,
    plan_source=program) — and time it as one signature."""
    m, d, n = 8, 64, 48
    b = GraphBuilder()
    x = b.input((m, d), "float32")
    ws = [b.input((d, n), "float32") for _ in range(3)]
    b.output(*(b.gemm(x, w, fmt="fp32") for w in ws))
    grouped = fuse_mod.fuse(b.build(), rules=(fuse_mod.group_siblings,))
    assert any(isinstance(nd, ir_mod.GroupNode) for nd in grouped.nodes)
    args = (_arr(m, d), _arr(d, n), _arr(d, n), _arr(d, n))
    with gemm_account.account_gemms() as acct:
        prog = compile_graph(grouped, fuse=False)
        prog(*args)
    assert len(acct.records) == 1            # the PR-8 suppression contract
    prof = DispatchProfiler(acct, iters=1)
    assert prof.sample() == 1                # one signature timed, not three
    rows = prof.calibration_table()
    assert len(rows) == 1
    (row,) = rows
    assert row.dispatches == 1 and row.grouped == 1
    assert row.plan_source == "program"
    assert row.signatures == 1 and row.sampled == 1
    assert row.measured_s > 0 and row.modeled_s > 0
    assert row.error_ratio == row.error_ratio   # finite join
    assert row.time_share == pytest.approx(1.0)


def test_calibration_covers_planned_and_unplanned_traffic():
    a, b = _arr(16, 64), _arr(64, 32)
    with gemm_account.account_gemms() as acct:
        dispatch.mte_gemm(a, b, backend="pallas")   # planner-granted
        dispatch.mte_gemm(a, b, backend="pallas")   # cache hit
        dispatch.mte_gemm(a, b, backend="xla")      # planner-bypassing
    prof = DispatchProfiler(acct, iters=1)
    assert prof.sample() == 2                       # 2 distinct signatures
    srcs = {r.plan_source for r in prof.calibration_table()}
    assert "unplanned" in srcs and ("analytic" in srcs or
                                    "measured" in srcs)
    assert "cache-hit" in srcs
    # the unplanned xla record still carries an analytic modeled time
    xla = [r for r in acct.records if r.backend == "xla"]
    assert xla and xla[0].modeled_s is not None and xla[0].modeled_s > 0
    # shares sum to 1 over measured rows
    assert sum(r.time_share for r in prof.calibration_table()) == \
        pytest.approx(1.0)
    # profiler's own measurement launches never pollute the account
    assert len(acct.records) == 3


def test_install_calibration_feeds_perfmodel():
    a, b = _arr(16, 64), _arr(64, 32)
    with gemm_account.account_gemms() as acct:
        dispatch.mte_gemm(a, b, backend="xla")
    prof = DispatchProfiler(acct, iters=1)
    prof.sample()
    assert prof.install_calibration() >= 1
    cal = perfmodel.calibration()
    assert cal and all(v > 0 for v in cal.values())
    base = perfmodel.analytic_seconds(16, 32, 64)
    scaled = perfmodel.calibrated_seconds(base, "tall_skinny", "fp32")
    key = "tall_skinny/fp32"
    if key in cal:
        assert scaled == pytest.approx(base * cal[key])
    with pytest.raises(ValueError):
        perfmodel.set_calibration("square", "fp32", float("inf"))
    perfmodel.clear_calibration()
    assert perfmodel.calibration() == {}


# -- plan-regret audit + recalibrate ------------------------------------------


def test_runner_up_differs_from_grant():
    a, b = _arr(64, 64), _arr(64, 48)
    dispatch.mte_gemm(a, b, backend="pallas")
    cache = autotune.plan_cache()
    (sig,) = list(cache._plans)
    granted = cache._plans[sig]
    runner = cache.runner_up(sig)
    assert runner is not None
    assert (runner.geometry != granted.geometry
            or runner.route != granted.route)
    assert cache.runner_up(dataclasses.replace(sig, m=999)) is None


def test_regret_audit_and_recalibrate():
    a, b = _arr(64, 64), _arr(64, 48)
    with gemm_account.account_gemms() as acct:
        dispatch.mte_gemm(a, b, backend="pallas")
        dispatch.mte_gemm(a, b, backend="pallas")
    prof = DispatchProfiler(acct, iters=1)
    prof.sample()
    audit = prof.regret_audit(top_k=2)
    assert len(audit) == 1
    (e,) = audit
    assert e["dispatches"] == 2
    assert e["granted_s"] > 0 and e["runner_s"] > 0
    assert isinstance(e["flagged"], bool)
    # recalibrate re-grants from measurement and replaces the entry
    cache = autotune.plan_cache()
    (sig,) = list(cache._plans)
    new = cache.recalibrate(sig)
    assert new.source == "measured" and new.measured_s is not None
    assert cache._plans[sig] is new
    summary = prof.summary()
    assert summary["regret"]["audited"] == 1
    assert summary["sampled"] >= 1


# -- SLO monitor --------------------------------------------------------------


def test_slo_vacuous_when_unobserved():
    mon = SloMonitor(default_slos())
    rep = mon.observe(step=1)
    assert rep.ok and not rep.breaching
    assert all(not s.observed for s in rep.statuses)


def test_slo_violation_breaching_and_burn_windows():
    reg = registry()
    reg.gauge("q.depth").set(50.0)
    t = [0.0]
    mon = SloMonitor(
        (Slo("depth", "q.depth", "max", 10.0),),
        windows=(Window("short", 1.0), Window("long", 10.0)),
        budget_frac=0.5, clock=lambda: t[0])
    r1 = mon.observe(step=1)
    (s1,) = r1.statuses
    assert not s1.ok and s1.observed and s1.value == 50.0
    # 100% bad / 50% budget = burn 2.0 in both windows -> breaching
    assert s1.burn_rates == {"short": 2.0, "long": 2.0}
    assert s1.breaching and r1.breaching == ("depth",)
    # metric recovers: ok again, short window empties of bad events
    reg.gauge("q.depth").set(1.0)
    t[0] = 2.0
    r2 = mon.observe(step=2)
    (s2,) = r2.statuses
    assert s2.ok and not s2.breaching
    assert s2.burn_rates["short"] == 0.0     # bad event aged out
    assert s2.burn_rates["long"] == 1.0      # 1 bad / 2 evals / 0.5 budget
    # verdict gauges + counters mirrored into the registry
    assert reg.get("slo.depth.ok").value == 1.0
    assert reg.get("slo.violations").value == 1.0
    assert reg.get("slo.evaluations").value == 2.0


def test_slo_ratio_and_min_objectives():
    reg = registry()
    reg.gauge("s.err").set(3.0)
    reg.gauge("s.total").set(100.0)
    reg.gauge("s.free").set(1.0)
    reg.gauge("s.cap").set(100.0)
    mon = SloMonitor((
        Slo("err_rate", "s.err", "max", 0.05, total="s.total"),
        Slo("headroom", "s.free", "min", 0.10, total="s.cap"),
    ))
    rep = mon.observe()
    by = {s.name: s for s in rep.statuses}
    assert by["err_rate"].ok and by["err_rate"].value == pytest.approx(0.03)
    assert not by["headroom"].ok
    assert by["headroom"].value == pytest.approx(0.01)
    # a zero denominator is "not observed", never a division crash
    reg.gauge("s.total").set(0.0)
    rep2 = mon.observe()
    assert {s.name: s.observed for s in rep2.statuses}["err_rate"] is False
    d = rep2.as_dict()
    assert isinstance(d["statuses"], list) and "ok" in d
    with pytest.raises(ValueError):
        Slo("bad", "x", "between", 1.0)
    with pytest.raises(ValueError):
        SloMonitor((Slo("a", "x", "max", 1.0),
                    Slo("a", "y", "max", 1.0)))


# -- health snapshot ----------------------------------------------------------


def test_health_schema_and_validation():
    doc = export_mod.health(timestamp=123.0)
    assert export_mod.validate_health(doc) == []
    assert doc["kv"] is None and doc["slo"] is None
    assert doc["generated_unix_s"] == 123.0
    # a wrong version and a sampled row with a non-finite ratio both fail
    bad = dict(doc, version=99)
    assert any("version" in e for e in export_mod.validate_health(bad))
    bad2 = dict(doc, calibration={"rows": [
        {"shape_class": "square", "fmt": "fp32", "plan_source": "x",
         "dispatches": 1, "sampled": 1, "error_ratio": float("nan")}]})
    assert any("error_ratio" in e for e in export_mod.validate_health(bad2))
    assert export_mod.validate_health([]) != []


def test_write_health_refuses_invalid(tmp_path, monkeypatch):
    path = tmp_path / "h.json"
    doc = export_mod.write_health(str(path), timestamp=1.0)
    assert path.exists() and doc["version"] == 1
    broken = dict(doc)
    del broken["registry"]
    monkeypatch.setattr(export_mod, "health", lambda **kw: broken)
    with pytest.raises(ValueError):
        export_mod.write_health(str(tmp_path / "h2.json"), timestamp=1.0)
    assert not (tmp_path / "h2.json").exists()


# -- graph.program spans ------------------------------------------------------


def test_graph_program_span_emitted_with_args():
    m, d, n = 8, 64, 48
    b = GraphBuilder()
    x = b.input((m, d), "float32")
    ws = [b.input((d, n), "float32") for _ in range(3)]
    b.output(*(b.gemm(x, w, fmt="fp32") for w in ws))
    grouped = fuse_mod.fuse(b.build(), rules=(fuse_mod.group_siblings,))
    prog = compile_graph(grouped, fuse=False)
    tr = tracing.install(tracing.Tracer())
    try:
        prog(_arr(m, d), _arr(d, n), _arr(d, n), _arr(d, n))
    finally:
        tracing.uninstall()
    spans = [e for e in tr.events if e["name"] == "graph.program"]
    assert len(spans) == 1
    args = spans[0]["args"]
    assert args["signature"] == prog.signature
    assert args["nodes"] == len(prog.graph.nodes)
    assert args["grouped"] == 1
    assert args["dispatches"] == prog.n_dispatches
    # validate_trace coverage extension: required names enforced
    assert tracing.validate_trace(tr.to_json(),
                                  require_names=("graph.program",)) == []
    errs = tracing.validate_trace(tr.to_json(),
                                  require_names=("nonexistent.span",))
    assert any("nonexistent.span" in e for e in errs)


def test_validate_trace_rejects_non_dict_args():
    doc = {"traceEvents": [{"name": "a", "ph": "i", "ts": 0, "pid": 1,
                            "tid": 1, "args": "oops"}]}
    assert any("args" in e for e in tracing.validate_trace(doc))


# -- engine integration: kv gauges + bit-identity with the stack on -----------


def _run_engine(params, cfg, prompts, max_tokens=5, **kw):
    engine = ServingEngine(params, cfg, slots=2, cache_len=64,
                           prefill_len=16, **kw)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_tokens=max_tokens))
    outputs = engine.run()
    return engine, outputs


def test_engine_publishes_kv_gauges_each_step():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [RNG.integers(0, cfg.vocab, size=7, dtype=np.int32)]
    engine, _ = _run_engine(params, cfg, prompts)
    reg = registry()
    desc = engine.sched.pool.describe()
    for key in desc:
        g = reg.get(f"kv.{key}")
        assert g is not None, key
        assert g.value == desc[key]          # final step's snapshot
    assert reg.get("serving.queue_depth").value == 0.0
    assert reg.get("serving.active_slots").value == 0.0
    assert reg.get("serving.finished_requests").value == len(prompts)


def test_engine_outputs_bit_identical_with_observability_stack():
    """The full PR-9 stack — profiler, SLO monitor, exporter, tracer,
    accountant — enabled end to end must not change a single greedy
    token vs a run with everything off."""
    cfg = _cfg()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=size, dtype=np.int32)
               for size in (5, 9, 13)]

    _, base = _run_engine(params, cfg, prompts)   # everything OFF

    reset_registry()
    autotune.reset_cache()
    sched_mod.reset_programs()
    tracer = tracing.install(tracing.Tracer())
    acct = gemm_account.install(gemm_account.GemmAccountant())
    mon = SloMonitor(default_slos(ttft_p99_s=300.0, error_rate=0.9,
                                  min_free_page_frac=0.0))
    try:
        engine, observed = _run_engine(params, cfg, prompts,
                                       slo_monitor=mon)
        # the full post-run observability pass
        prof = DispatchProfiler(acct, iters=1)
        prof.sample()
        prof.regret_audit(top_k=2)
        text = export_mod.render_prometheus()
        doc = export_mod.health(engine=engine, profiler=prof,
                                slo_report=mon.last_report)
    finally:
        tracing.uninstall()
        gemm_account.uninstall()

    assert {r: list(v) for r, v in observed.items()} == \
        {r: list(v) for r, v in base.items()}

    # and the stack actually observed the run
    assert mon.evaluations == engine.step_idx
    assert mon.last_report is not None and mon.last_report.ok
    assert export_mod.validate_health(doc) == []
    assert doc["slo"]["ok"] is True
    assert doc["calibration"]["sampled"] >= 1
    assert doc["kv"]["num_pages"] == engine.sched.pool.num_pages
    parsed = export_mod.parse_prometheus(text)
    assert any(k.startswith("kv_") for k in parsed)
    assert any(k.startswith("slo_") for k in parsed)
    assert tracing.validate_trace(tracer.to_json()) == []
