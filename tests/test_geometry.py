"""Formula 2/3 tile solvers + TPU BlockSpec solver invariants."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # hermetic env: run properties via the local shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.geometry import (
    PROFILES, TPU_V5E, max_tile_dims, sifive_tile_dims, solve_block_geometry,
    solve_unroll,
)
from repro.core.tile_state import SEW


def test_formula2_paper_example():
    """§III-A2: VLEN 8192, RLEN 512, SEW 32 → 16×16×16 uniform."""
    t = max_tile_dims(PROFILES["mte32s"], SEW.E32)
    assert t.mnk == (16, 16, 16) and not t.transposed_b
    # full register utilization on all operands (paper: 256 elements)
    assert t.m * t.n == 256


def test_formula3_paper_example():
    """§III-A2: SEW_o=32, SEW_i=16 → 16×16×32 with transposed B."""
    t = max_tile_dims(PROFILES["mte32s"], SEW.E16, SEW.E32)
    assert t.mnk == (16, 16, 32) and t.transposed_b
    # 256 output elements, 512 input elements — full capacity
    assert t.m * t.n == 256 and t.k * t.n == 512 * 16 // 16


def test_vector_degenerate_geometry():
    """Table VII: vector ISAs have 1×VL×1 geometry."""
    assert max_tile_dims(PROFILES["vector1k"], SEW.E32).mnk == (1, 256, 1)
    assert max_tile_dims(PROFILES["vector2k"], SEW.E32).mnk == (1, 512, 1)


def test_sifive_geometry():
    """§V-C: VLEN 8192 fp32 → 4×64×4."""
    assert sifive_tile_dims(PROFILES["sifiveint"], SEW.E32).mnk == (4, 64, 4)


@settings(max_examples=150, deadline=None)
@given(m=st.integers(1, 8192), n=st.integers(1, 8192), k=st.integers(1, 8192),
       arch=st.sampled_from(["mte8s", "mte32s", "mte32v", "sifiveint"]))
def test_unroll_respects_register_budget(m, n, k, arch):
    prof = PROFILES[arch]
    tile = (sifive_tile_dims(prof, SEW.E32) if arch == "sifiveint"
            else max_tile_dims(prof, SEW.E32))
    plan = solve_unroll(prof, tile, m, n, k)
    assert plan.live_regs <= prof.arch_regs
    assert plan.um >= 1 and plan.un >= 1


def test_amx_register_budget_forces_smaller_unroll():
    """The 8-register AMX budget cannot reach the 32-register unroll —
    the mechanism behind the paper's 1.35× (§VI-A)."""
    t8 = max_tile_dims(PROFILES["mte8s"], SEW.E32)
    t32 = max_tile_dims(PROFILES["mte32s"], SEW.E32)
    p8 = solve_unroll(PROFILES["mte8s"], t8, 2048, 2048, 2048)
    p32 = solve_unroll(PROFILES["mte32s"], t32, 2048, 2048, 2048)
    assert p8.indep_chains < p32.indep_chains
    assert p8.live_regs <= 8


@settings(max_examples=150, deadline=None)
@given(m=st.integers(1, 65536), n=st.integers(1, 65536),
       k=st.integers(1, 65536),
       sew=st.sampled_from([SEW.E8, SEW.E16, SEW.E32]),
       policy=st.sampled_from(["mte", "amx", "vector", "sifive"]))
def test_block_geometry_invariants(m, n, k, sew, policy):
    sew_o = SEW.E32
    g = solve_block_geometry(m, n, k, sew, sew_o, policy=policy)
    # hardware alignment: lane multiple on N, sublane multiple on M
    assert g.bn % TPU_V5E.lane == 0 or g.bn >= n
    assert g.bm % TPU_V5E.sublane(sew) == 0 or g.bm >= m
    assert g.bm > 0 and g.bn > 0 and g.bk > 0
    if policy == "mte":
        # VMEM budget respected (the paper's register-capacity analogue)
        assert g.vmem_bytes() <= TPU_V5E.vmem_bytes * TPU_V5E.vmem_budget_frac
        # mixed precision flags transposed B (Formula 3)
        assert g.transposed_b == (sew.bits < sew_o.bits)
    if policy == "amx":
        assert (g.bm, g.bn, g.bk) == (128, 128, 128)  # rigid, by design


@settings(max_examples=100, deadline=None)
@given(m=st.integers(1, 4096), n=st.integers(1, 4096), k=st.integers(1, 4096))
def test_mte_adapts_small_dims_amx_does_not(m, n, k):
    """Geometry agnosticism: MTE blocks never exceed the (aligned) problem;
    the rigid baseline always pads to 128."""
    g = solve_block_geometry(m, n, k, SEW.E32, SEW.E32, policy="mte")
    assert g.bm <= max(8, -(-m // 8) * 8) * 2 or g.bm <= 512
    if m <= 8:
        assert g.bm == 8
    if n <= 128:
        assert g.bn == 128
