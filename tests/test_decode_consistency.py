"""Serving-path correctness: prefill + cached decode must reproduce the
full-forward logits for every architecture family (KV ring buffers, SSD
state, RG-LRU state, MoE routing all exercised)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import model as model_lib

B, S, EXTRA = 2, 24, 3


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = model_lib.init_params(key, cfg)
    total = S + EXTRA

    if cfg.frontend_stub:
        emb = jax.random.normal(key, (B, total, cfg.d_model),
                                jnp.float32) * 0.1
        full_batch = {"embeddings": emb,
                      "targets": jnp.zeros((B, total), jnp.int32)}
        prefill_batch = {"embeddings": emb[:, :S]}
        dec_batch = lambda i: {"embeddings": emb[:, S + i: S + i + 1],
                               "pos": jnp.int32(S + i)}
    else:
        tokens = jax.random.randint(key, (B, total), 0, cfg.vocab)
        full_batch = {"tokens": tokens}
        prefill_batch = {"tokens": tokens[:, :S]}
        dec_batch = lambda i: {"tokens": tokens[:, S + i: S + i + 1],
                               "pos": jnp.int32(S + i)}

    full_logits, _ = model_lib.forward(params, full_batch, cfg)
    pf_logits, cache = model_lib.prefill(params, prefill_batch, cfg,
                                         cache_len=total + 4)
    np.testing.assert_allclose(pf_logits, full_logits[:, S - 1],
                               rtol=3e-3, atol=3e-3)
    # multi-step decode stays consistent (state/cache carried correctly)
    for i in range(EXTRA):
        dec_logits, cache = model_lib.decode(params, dec_batch(i), cache, cfg)
        np.testing.assert_allclose(dec_logits, full_logits[:, S + i],
                                   rtol=8e-3, atol=8e-3)


def test_vectorized_positions_match_scalar():
    """Per-sequence decode positions (continuous batching) must equal the
    scalar-position path when all slots share the position."""
    cfg = get_config("gemma_2b").reduced()
    key = jax.random.PRNGKey(3)
    params = model_lib.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    _, cache1 = model_lib.prefill(params, {"tokens": tokens[:, :S]}, cfg,
                                  cache_len=S + 4)
    cache2 = jax.tree.map(jnp.copy, cache1)
    d1, _ = model_lib.decode(params, {"tokens": tokens[:, S:],
                                      "pos": jnp.int32(S)}, cache1, cfg)
    d2, _ = model_lib.decode(params, {"tokens": tokens[:, S:],
                                      "pos": jnp.full((B,), S, jnp.int32)},
                             cache2, cfg)
    np.testing.assert_allclose(d1, d2, rtol=1e-6, atol=1e-6)


def test_sliding_window_ring_cache_is_bounded():
    """local-attention caches hold window slots, not seq_len — the
    long_500k memory requirement."""
    cfg = get_config("starcoder2_7b").reduced()  # window=16 reduced
    cache = model_lib.init_cache(cfg, batch=2, seq_len=10_000)
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 5:  # (G, B, S_cache, kv, hd)
            assert leaf.shape[2] == cfg.window


def test_ssm_cache_is_constant_size():
    cfg = get_config("mamba2_130m").reduced()
    c1 = model_lib.init_cache(cfg, batch=2, seq_len=100)
    c2 = model_lib.init_cache(cfg, batch=2, seq_len=1_000_000)
    assert jax.tree.map(lambda x: x.shape, c1) == \
        jax.tree.map(lambda x: x.shape, c2)


def test_int8_kv_cache_decode_close_to_fp():
    """Quantized KV cache: decode logits stay close to the fp cache path
    (int8 per-token-head symmetric quantization)."""
    import dataclasses
    cfg = get_config("gemma_2b").reduced()
    cfg_q = dataclasses.replace(cfg, cache_quant=True)
    key = jax.random.PRNGKey(5)
    params = model_lib.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    _, cache = model_lib.prefill(params, {"tokens": tokens[:, :S]}, cfg,
                                 cache_len=S + 4)
    _, cache_q = model_lib.prefill(params, {"tokens": tokens[:, :S]}, cfg_q,
                                   cache_len=S + 4)
    assert cache_q["groups"][0]["k"].dtype == jnp.int8
    batch = {"tokens": tokens[:, S:], "pos": jnp.int32(S)}
    d_fp, _ = model_lib.decode(params, batch, cache, cfg)
    d_q, cache_q2 = model_lib.decode(params, batch, cache_q, cfg_q)
    # int8 cache ⇒ small quantization error, same argmax behaviour
    err = np.max(np.abs(np.asarray(d_q) - np.asarray(d_fp)))
    rng_span = np.max(np.abs(np.asarray(d_fp))) + 1e-6
    assert err / rng_span < 0.05, err
    assert np.array_equal(np.argmax(np.asarray(d_q), -1),
                          np.argmax(np.asarray(d_fp), -1))
    # footprint: int8 values + f32/hd scales ≈ 0.56x of bf16
    def nbytes(c):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c)
                   if x.ndim >= 4)
    assert nbytes(cache_q2) < 0.7 * nbytes(cache) * 2  # vs bf16(2B)/f32 mix
