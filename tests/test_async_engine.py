"""Async pipelined engine step: bit-identity, flush boundaries, buffer
donation, work conservation, the trace-overlap witness, and the graph
weight-prefetch plan.

The contract under test: ``async_steps=True`` changes *when* sampled
tokens reach the host (delivery lags launch by up to one step), never
*which* tokens any request receives — both modes run the identical
jitted decode+sample program, so greedy outputs are bit-identical by
construction, and these tests pin that construction against drift.
"""
import dataclasses
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving import Request, ServingEngine
from repro.serving.resilience import Fault, FaultInjector
from repro.telemetry import tracing
from repro.telemetry.export import health, validate_health


def _cfg():
    cfg = get_config("gemma_2b").reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                               vocab=128, n_heads=2, n_kv_heads=1,
                               head_dim=32)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _workload(cfg, n_req=5, lo=10, hi=16, base_tokens=6):
    """Staggered prompts/budgets: multi-chunk prefills and unequal
    finish steps, so admissions and continuing chunks land while a
    decode is in flight (the depth-2 window)."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(lo, hi)),
                            dtype=np.int32) for _ in range(n_req)]
    budgets = [base_tokens + (i % 3) * 2 for i in range(n_req)]
    return prompts, budgets


def _serve(params, cfg, prompts, budgets, *, async_steps, **kw):
    eng = ServingEngine(params, cfg, slots=2, cache_len=64,
                        prefill_len=16, page_size=8, prefill_chunk=8,
                        async_steps=async_steps, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_tokens=budgets[rid]))
    out = eng.run()
    return {rid: tuple(r) for rid, r in out.items()}, eng


# -- greedy bit-identity ------------------------------------------------------


@pytest.mark.parametrize("arch,spec_k", [("gemma_2b", 0),
                                         ("gemma_2b", 2),
                                         ("recurrentgemma_9b", 0)])
def test_greedy_bit_identity_async_on_off(arch, spec_k):
    """Same workload, async on vs off: identical token streams — across
    a pure-attention arch, a hybrid recurrent arch (per-slot carried
    state rides ``row_valid`` through the pipelined decode), and with
    speculation (which flushes to its own synchronous verify step)."""
    if arch == "gemma_2b":
        cfg = _cfg()
    else:
        cfg = get_config(arch).reduced()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    prompts, budgets = _workload(cfg, n_req=4)
    sync_toks, _ = _serve(params, cfg, prompts, budgets,
                          async_steps=False, spec_k=spec_k)
    async_toks, eng = _serve(params, cfg, prompts, budgets,
                             async_steps=True, spec_k=spec_k)
    assert async_toks == sync_toks
    assert all(len(t) > 0 for t in async_toks.values())
    if spec_k == 0:
        assert eng.metrics()["delivery_lag_mean"] > 0.0


def test_greedy_bit_identity_under_mid_run_eviction(setup):
    """A pool small enough to force preemption mid-run: the eviction
    boundary flushes the pipeline before the victim's host-visible
    output is requeued, so replay produces the same tokens either way."""
    cfg, params = setup
    prompts, budgets = _workload(cfg, n_req=3, base_tokens=10)
    sync_toks, sync_eng = _serve(params, cfg, prompts, budgets,
                                 async_steps=False, num_pages=7)
    async_toks, async_eng = _serve(params, cfg, prompts, budgets,
                                   async_steps=True, num_pages=7)
    assert async_toks == sync_toks
    # the scenario only bites if someone actually got preempted
    assert sync_eng.metrics()["preemptions"] >= 1
    assert async_eng.metrics()["preemptions"] >= 1


# -- flush boundaries and pipeline depth --------------------------------------


def test_snapshot_flushes_pipeline_and_health_reports_staleness(setup):
    """Mid-flight: ``steps_in_flight`` > 0, the health snapshot carries
    the staleness note (and validates); ``snapshot()`` is a flush
    boundary, so afterwards nothing is in flight."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, slots=2, cache_len=64,
                        prefill_len=16, page_size=8, async_steps=True)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_tokens=12))
    eng._admit()
    for _ in range(3):
        eng.step()
    assert eng.steps_in_flight >= 1
    doc = health(engine=eng)
    assert validate_health(doc) == []
    assert doc["scheduler"]["steps_in_flight"] >= 1
    assert "lag" in doc["scheduler"]["staleness"]
    eng.snapshot()
    assert eng.steps_in_flight == 0
    out = eng.run()
    assert len(out[0]) == 12


def test_pipeline_reaches_depth_two(setup):
    cfg, params = setup
    prompts, budgets = _workload(cfg)
    _, eng = _serve(params, cfg, prompts, budgets, async_steps=True)
    assert eng.steps_in_flight_max >= 2
    assert eng.steps_in_flight == 0      # run() end is a flush boundary
    _, sync_eng = _serve(params, cfg, prompts, budgets, async_steps=False)
    assert sync_eng.steps_in_flight_max <= 1


def test_fault_injection_forces_synchronous_depth(setup):
    """An armed injector pins the effective depth to 1: poison/sample
    overrides are host-side and must fire in the decode's own step."""
    cfg, params = setup
    prompts, budgets = _workload(cfg, n_req=3)
    inj = FaultInjector([Fault("poison_logits", rid=1, step=4)])
    toks, eng = _serve(params, cfg, prompts, budgets,
                       async_steps=True, fault=inj)
    assert eng.steps_in_flight_max <= 1
    assert all(len(t) > 0 for rid, t in toks.items() if rid != 1)


def test_work_conservation_vs_sync(setup):
    """Async must not burn steps: delivered finishes are re-admitted in
    the same step (second admission pass), so the step-count overhead
    is bounded by trailing drain-only steps — never bubble decodes."""
    cfg, params = setup
    prompts, budgets = _workload(cfg)
    _, sync_eng = _serve(params, cfg, prompts, budgets, async_steps=False)
    _, async_eng = _serve(params, cfg, prompts, budgets, async_steps=True)
    assert async_eng.step_idx - sync_eng.step_idx <= 3
    assert async_eng.metrics()["delivery_lag_mean"] == pytest.approx(1.0)
    assert sync_eng.metrics()["delivery_lag_mean"] == 0.0


# -- donation -----------------------------------------------------------------


def test_decode_steps_do_not_grow_live_buffers(setup):
    """The decode program donates the KV cache and carries the token
    array on device: consecutive steps must not accumulate live device
    buffers (each step's outputs replace the previous step's)."""
    if not hasattr(jax, "live_arrays"):
        pytest.skip("jax.live_arrays not available")
    cfg, params = setup
    eng = ServingEngine(params, cfg, slots=2, cache_len=64,
                        prefill_len=16, page_size=8, async_steps=True)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_tokens=40))
    eng._admit()
    for _ in range(4):   # warm: compile, seed, reach steady decode state
        eng.step()
    gc.collect()
    counts = []
    for _ in range(4):
        eng.step()
        gc.collect()
        counts.append(len(jax.live_arrays()))
    assert max(counts) == min(counts), counts


# -- trace witness ------------------------------------------------------------


def test_trace_decode_overlaps_next_step_host_work(setup):
    """The async decode span stays open until delivery, so it must
    overlap the NEXT step's host spans (prefill chunks, delivery
    sampling); the synchronous trace must show no decode x
    prefill_chunk overlap — pipelining, not span bookkeeping."""
    cfg, params = setup
    prompts, budgets = _workload(cfg)

    def traced(async_steps):
        tr = tracing.install(tracing.Tracer())
        try:
            _serve(params, cfg, prompts, budgets, async_steps=async_steps)
        finally:
            tracing.uninstall()
        return tr.to_json()

    doc = traced(True)
    assert tracing.span_overlaps(doc, "decode", "prefill_chunk")
    assert tracing.span_overlaps(doc, "decode", "sample")
    assert tracing.validate_trace(
        doc, require_names=("decode", "prefill_chunk", "admit"),
        require_overlap=(("decode", "prefill_chunk"),
                         ("decode", "sample"))) == []
    sync_doc = traced(False)
    assert not tracing.span_overlaps(sync_doc, "decode", "prefill_chunk")


def test_span_overlaps_and_validate_trace_unit():
    def ev(name, ts, dur):
        return {"name": name, "ph": "X", "ts": ts, "dur": dur,
                "pid": 1, "tid": 1}

    doc = {"traceEvents": [ev("a", 0, 10), ev("b", 5, 10),
                           ev("c", 20, 5)]}
    assert tracing.span_overlaps(doc, "a", "b")
    assert not tracing.span_overlaps(doc, "a", "c")
    # touching endpoints are NOT overlap (strict inequalities)
    doc2 = {"traceEvents": [ev("a", 0, 10), ev("b", 10, 10)]}
    assert not tracing.span_overlaps(doc2, "a", "b")
    errs = tracing.validate_trace(doc, require_overlap=(("a", "c"),))
    assert any("'a' x 'c'" in e for e in errs)
    assert tracing.validate_trace(doc, require_overlap=(("a", "b"),)) == []


# -- graph weight prefetch ----------------------------------------------------


def test_graph_emits_weight_prefetch_plan():
    """Cross-layer double-buffering: a two-GEMM chain prefetches the
    second layer's (graph-input) weights during the first's compute.
    ``modeled_s`` stays the no-overlap figure — baselines and fusion
    scoring are unchanged; the saving is annotated separately."""
    from repro.graph import GraphBuilder, compile_graph

    rng = np.random.default_rng(0)

    def build():
        b = GraphBuilder()
        x = b.input((8, 32), "float32")
        w1 = b.input((32, 32), "float32")
        w2 = b.input((32, 24), "float32")
        b.output(b.gemm(b.gemm(x, w1, fmt="fp32"), w2, fmt="fp32"))
        return b.build()

    args = (jnp.asarray(rng.standard_normal((8, 32)), jnp.float32),
            jnp.asarray(rng.standard_normal((32, 32)), jnp.float32),
            jnp.asarray(rng.standard_normal((32, 24)), jnp.float32))
    prog = compile_graph(build(), fuse=False, prefetch=True)
    assert prog.prefetch and prog.prefetch_saved_s > 0.0
    assert "prefetch" in prog.describe()
    off = compile_graph(build(), fuse=False, prefetch=False)
    assert off.prefetch == {} and off.prefetch_saved_s == 0.0
    assert prog.modeled_s == off.modeled_s
    np.testing.assert_array_equal(np.asarray(prog(*args)),
                                  np.asarray(off(*args)))
