"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests in this repo use a small slice of the hypothesis API:
``given`` / ``settings`` decorators and the ``integers`` / ``floats`` /
``sampled_from`` strategies.  When the real package is available the test
modules import it; otherwise they fall back to this shim so the properties
still execute (deterministic pseudo-random sampling, boundary values
first) instead of the whole module being skipped.

This is intentionally tiny: no shrinking, no database, no assume().  Its
only job is to keep the property suites running in hermetic environments.
Install the real ``hypothesis`` (see requirements-dev.txt) for full
coverage.
"""
from __future__ import annotations

import functools
import inspect
import random

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 25


class _Strategy:
    """A sampleable value source with explicit boundary examples."""

    def __init__(self, sample, boundaries):
        self._sample = sample
        self.boundaries = list(boundaries)

    def sample(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     [min_value, max_value])


def floats(min_value: float, max_value: float, allow_nan: bool = True,
           allow_infinity: bool = True) -> _Strategy:
    del allow_nan, allow_infinity  # this shim never generates nan/inf
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     [min_value, max_value])


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    bounds = [elements[0], elements[-1]] if elements else []
    return _Strategy(lambda rng: rng.choice(elements), bounds)


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.choice([False, True]), [False, True])


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)


strategies = _StrategiesModule()


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Record the example budget on the (already-wrapped) test function."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test once per generated example.

    The first examples exercise the strategies' boundary values (all-min,
    then all-max); the rest are drawn from a deterministic RNG seeded by
    the test name, so failures are reproducible run to run.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(fn.__qualname__)
            strategies_all = list(arg_strategies) + list(kw_strategies.values())
            n_bounds = max((len(s.boundaries) for s in strategies_all),
                           default=0)
            for i in range(max(1, n)):
                if i < n_bounds:
                    draw = [s.boundaries[min(i, len(s.boundaries) - 1)]
                            if s.boundaries else s.sample(rng)
                            for s in strategies_all]
                else:
                    draw = [s.sample(rng) for s in strategies_all]
                pos = draw[:len(arg_strategies)]
                kw = dict(zip(kw_strategies, draw[len(arg_strategies):]))
                fn(*args, *pos, **kwargs, **kw)

        # Hide the strategy parameters from pytest's fixture collection.
        # Positional strategies bind to the RIGHTMOST parameters (like
        # real hypothesis), leaving leading fixture params for pytest.
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in kw_strategies]
        if arg_strategies:
            keep = keep[:-len(arg_strategies)]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper

    return deco
