"""Multi-device behaviour tests (8 forced host devices in a subprocess so
the main test process keeps its single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """The 2×4 (data×model) pjit'd train step must produce the same loss
    trajectory as unsharded execution — sharding is semantics-free."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_config
    from repro.distributed import sharding as sh
    from repro.models import model as M
    from repro.optim.optimizer import AdamWConfig, init_opt_state
    from repro.training.trainer import make_train_step

    cfg = get_config('gemma_2b').reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                              vocab=256, n_heads=4, n_kv_heads=1, head_dim=16)
    key = jax.random.PRNGKey(0)
    batch = {'tokens': jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    opt_cfg = AdamWConfig(lr=1e-3)
    step = make_train_step(cfg, opt_cfg)

    # single device
    params = M.init_params(key, cfg)
    opt = init_opt_state(params)
    _, _, m1 = jax.jit(step)(params, opt, batch)

    # 2x4 mesh, full sharding stack
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    from repro.distributed import compat
    with compat.set_mesh(mesh):
        pshape = jax.eval_shape(lambda: M.init_params(key, cfg))
        pspec = sh.param_specs(cfg, pshape, mesh)
        pshard = sh.named_shardings(mesh, pspec)
        params2 = jax.jit(lambda k: M.init_params(k, cfg),
                          out_shardings=pshard)(key)
        opt2 = jax.jit(init_opt_state)(params2)
        bshard = sh.named_shardings(mesh, sh.batch_specs(mesh, batch))
        batch2 = jax.device_put(batch, bshard)
        _, _, m2 = jax.jit(step)(params2, opt2, batch2)

    l1, l2 = float(m1['loss']), float(m2['loss'])
    # f32 reduction order differs between the sharded and unsharded
    # graphs (GSPMD reduce-scatter vs single-device sums); observed
    # drift on jax 0.4.x CPU is ~7e-3 at loss ~5.5, so bound at 1e-2 —
    # still catches real semantic divergence (>0.2%), not bitwise.
    assert abs(l1 - l2) < 1e-2, (l1, l2)
    print('OK', l1, l2)
    """)
    assert "OK" in out


def test_moe_a2a_matches_scatter_path():
    """The explicit all-to-all expert-parallel MoE must agree with the
    GSPMD scatter path (ample capacity, 4-way EP)."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_config
    from repro.models import moe as moe_mod

    cfg = get_config('qwen3_moe_235b').reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.3

    want, aux1 = moe_mod.apply_moe(x, p, cfg)

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    from repro.distributed import compat
    with compat.set_mesh(mesh):
        got, aux2 = jax.jit(
            lambda x, p: moe_mod.apply_moe_a2a(x, p, cfg, mesh=mesh,
                                               token_axes=('data',)))(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print('OK')
    """)
    assert "OK" in out


def test_elastic_restore_reshards():
    """Checkpoint saved from a 1×8 mesh restores onto a 4×2 mesh (device
    loss / elastic rescale) with identical values."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile, dataclasses
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.distributed import sharding as sh
    from repro.models import model as M
    from repro.optim.optimizer import init_opt_state

    cfg = get_config('gemma_2b').reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                              vocab=256, n_heads=4, n_kv_heads=1, head_dim=16)
    key = jax.random.PRNGKey(0)
    tmp = tempfile.mkdtemp()

    mesh1 = jax.make_mesh((1, 8), ('data', 'model'))
    from repro.distributed import compat
    with compat.set_mesh(mesh1):
        pshape = jax.eval_shape(lambda: M.init_params(key, cfg))
        shard1 = sh.named_shardings(mesh1, sh.param_specs(cfg, pshape, mesh1))
        params = jax.jit(lambda k: M.init_params(k, cfg),
                         out_shardings=shard1)(key)
        opt = jax.jit(init_opt_state)(params)
        mgr = CheckpointManager(tmp)
        mgr.save(5, params, opt)

    mesh2 = jax.make_mesh((4, 2), ('data', 'model'))
    with compat.set_mesh(mesh2):
        oshape = jax.eval_shape(init_opt_state, pshape)
        shard2p = sh.named_shardings(mesh2, sh.param_specs(cfg, pshape, mesh2))
        shard2o = {'m': shard2p, 'v': shard2p,
                   'step': jax.sharding.NamedSharding(
                       mesh2, jax.sharding.PartitionSpec())}
        p2, o2, man = CheckpointManager(tmp).restore(
            None, (pshape, oshape), (shard2p, shard2o))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert man['step'] == 5
    print('OK')
    """)
    assert "OK" in out


def test_multipod_mesh_axes():
    out = _run("""
    import jax
    from repro.launch.mesh import make_elastic_mesh
    mesh = make_elastic_mesh(8, model_parallel=4)
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \
        {'data': 2, 'model': 4}
    mesh2 = make_elastic_mesh(6, model_parallel=4)  # degraded fleet
    assert mesh2.devices.size == 6
    print('OK')
    """)
    assert "OK" in out
