"""Speculative decoding: greedy bit-identity with vanilla decode,
rejection-sampling distribution invariance, rewind correctness under
eviction/chaos, load-degraded speculation depth, and the prefix-index
persistence that rides along in this PR."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import KVPagePool
from repro.serving.resilience import Fault, FaultInjector
from repro.serving.scheduler import ContinuousBatchingScheduler


def _tiny(name):
    """Both target archs shrunk to test scale with ≥ 2 scan groups, so
    the default draft (first group, weight-shared) is a real truncation
    that gets rejected often — the rewind path is the test subject."""
    cfg = get_config(name).reduced()
    return dataclasses.replace(cfg, n_layers=2 * cfg.period, d_model=64,
                               d_ff=128, vocab=128, n_heads=2,
                               n_kv_heads=1, head_dim=32)


def _submit_shared(engine, cfg, n=3, seed=5, max_tokens=12):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, 20, dtype=np.int32)
    for rid in range(n):
        tail = rng.integers(0, cfg.vocab, 4 + 2 * rid, dtype=np.int32)
        engine.submit(Request(rid=rid,
                              prompt=np.concatenate([shared, tail]),
                              max_tokens=max_tokens))


def _run(params, cfg, spec_k, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("cache_len", 96)
    kw.setdefault("prefill_len", 32)
    kw.setdefault("page_size", 16)
    eng = ServingEngine(params, cfg, spec_k=spec_k, debug_audit=True, **kw)
    _submit_shared(eng, cfg)
    out = eng.run(max_steps=300)
    return {rid: list(r) for rid, r in out.items()}, eng


# -- greedy bit-identity ------------------------------------------------------


@pytest.mark.parametrize("arch", ["gemma2_27b", "recurrentgemma_9b"])
def test_greedy_bit_identical_to_vanilla(arch):
    """The acceptance bar: speculative greedy output must be the same
    token stream vanilla decode produces, bit for bit — acceptance reads
    the exact logits a vanilla step would compute, and rejected drafts
    rewind without a trace (including the ring/recurrent replay path on
    gemma2's local layers and recurrentgemma's RG-LRU rows)."""
    cfg = _tiny(arch)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    vanilla, _ = _run(params, cfg, spec_k=0)
    spec, eng = _run(params, cfg, spec_k=4)
    assert spec == vanilla
    m = eng.metrics()
    assert m["spec_steps"] > 0
    assert 0.0 < m["acceptance_rate"] < 1.0  # rejections were exercised
    eng.sched.pool.audit()


def test_spec_step_emits_multiple_tokens_on_agreement():
    """When draft == target (draft_groups = all groups), every proposal
    is accepted and each verify step emits the full window."""
    cfg = _tiny("gemma2_27b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    vanilla, _ = _run(params, cfg, spec_k=0)
    spec, eng = _run(params, cfg, spec_k=4, draft_groups=2)
    assert spec == vanilla
    m = eng.metrics()
    assert m["acceptance_rate"] == 1.0
    # k-1 drafts kept per slot every step (the counter sums over slots)
    assert m["accepted_per_step"] >= 3.0


# -- rejection sampling preserves the target distribution ---------------------


def test_rejection_sampling_matches_target_marginal():
    """Seeded stats: the first emitted token of a speculative step is
    distributed per the TARGET softmax, whatever the draft proposes —
    the canonical accept-w.p.-min(1, p_t/p_d) + residual-resample
    invariance, checked empirically against both a close and a hostile
    draft distribution."""
    cfg = _tiny("gemma2_27b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64,
                        prefill_len=32, seed=123)
    req = Request(rid=0, prompt=np.zeros(4, np.int32), temperature=1.0)
    rng = np.random.default_rng(0)
    V, k = 8, 3
    t_logits = rng.normal(size=V) * 2.0
    p_t = np.exp(t_logits - t_logits.max())
    p_t /= p_t.sum()
    for d_logits in [t_logits + rng.normal(size=V) * 0.5,  # decent draft
                     -2.0 * t_logits]:                      # hostile draft
        trials = 4000
        counts = np.zeros(V)
        logits = np.tile(t_logits, (k, 1))
        dlog = np.tile(d_logits, (k, 1))
        for _ in range(trials):
            props = [eng._propose(d_logits, req) for _ in range(k - 1)]
            emit, _ = eng._accept(logits, props, dlog, req)
            counts[emit[0]] += 1
        emp = counts / trials
        np.testing.assert_allclose(emp, p_t, atol=0.035)


# -- rewind under eviction / chaos --------------------------------------------


def test_spec_outputs_survive_eviction_rewind():
    """Overcommitted pool: eviction fires while speculation is active.
    The evicted request resumes through re-prefill (its window covers
    its whole context here), so greedy outputs must still match the
    uncontended vanilla run — and the pool audit stays green."""
    cfg = _tiny("gemma2_27b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    # prompt + output fits in the prefill window -> eviction-invariant
    kw = dict(slots=2, cache_len=96, prefill_len=64, page_size=16)
    van, _ = _run(params, cfg, spec_k=0, **kw)
    # usable pages 8: two prefills fill the pool; the first decode
    # growth must evict the youngest occupant, which later resumes.
    spec, eng = _run(params, cfg, spec_k=4, num_pages=9, **kw)
    m = eng.metrics()
    assert m["preemptions"] > 0, "pool must have been overcommitted"
    assert m["spec_steps"] > 0, "speculation must have run around it"
    assert spec == van
    eng.sched.pool.audit()


def test_poisoned_slot_quarantined_only_under_spec():
    """poison_logits against one rid during speculative decode: that
    request is cancelled with status 'poisoned'; every other request's
    tokens are bit-identical to a fault-free speculative run."""
    cfg = _tiny("gemma2_27b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    clean, _ = _run(params, cfg, spec_k=4)

    eng = ServingEngine(params, cfg, slots=2, cache_len=96,
                        prefill_len=32, page_size=16, spec_k=4,
                        debug_audit=True,
                        fault=FaultInjector(
                            [Fault("poison_logits", rid=0, step=6)]))
    _submit_shared(eng, cfg)
    out = eng.run(max_steps=300)
    assert out[0].status == "poisoned"
    assert len(out[0]) < len(clean[0])  # partial output returned
    for rid in (1, 2):
        assert out[rid].status == "ok"
        assert list(out[rid]) == clean[rid]
    eng.sched.pool.audit()


# -- load-degraded speculation depth ------------------------------------------


def test_scheduler_spec_k_degrades_on_full_pool():
    """Unit: the spec_k policy hook returns depth 1 (vanilla decode)
    when the free list is empty — speculation sheds before anything
    else, and never causes an eviction."""
    sched = ContinuousBatchingScheduler(slots=2, max_seq_len=64,
                                        page_size=8, num_pages=8)
    assert sched.spec_k(0) is None          # no decoders: no cap needed
    assert sched.spec_k(1) > 1              # empty pool: plenty of room
    assert sched.pool.ensure(0, sched.pool.free_pages * 8)  # drain it
    assert sched.pool.free_pages == 0
    assert sched.spec_k(1) == 1
    assert sched.spec_k(2) == 1


def test_full_pool_degrades_spec_without_evicting():
    """Integration: a pool sized so decode growth drains the free list
    forces k -> 1 steps (spec_steps < decode_steps) but never a
    preemption; outputs still match the vanilla engine on the same
    geometry."""
    cfg = _tiny("gemma2_27b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    # usable pages 6 = 2 slots x (2 prefill + 1 growth): the free list
    # hits zero as soon as both slots grow past the window.
    kw = dict(slots=2, cache_len=64, prefill_len=32, page_size=16,
              num_pages=7)
    van, _ = _run(params, cfg, spec_k=0, **kw)
    spec, eng = _run(params, cfg, spec_k=4, **kw)
    assert spec == van
    m = eng.metrics()
    assert m["preemptions"] == 0, "depth must shed before eviction"
    assert 0 < m["spec_steps"] < m["decode_steps"], \
        "some steps must have degraded to vanilla (k=1)"


# -- prefix-index persistence -------------------------------------------------


def test_pool_prefix_index_roundtrip(tmp_path):
    pool = KVPagePool(num_pages=8, page_size=4)
    assert pool.ensure(0, 12)  # 3 pages
    pool.register(0, 0, "h0")
    pool.register(0, 1, "h1")
    path = str(tmp_path / "prefix.json")
    assert pool.save_index(path) == 2
    fresh = KVPagePool(num_pages=8, page_size=4)
    assert fresh.load_index(path) == 2
    assert fresh.lookup_prefix(["h0", "h1"]) == 2
    fresh.audit()
    # geometry mismatch must refuse (a stale file from another engine)
    other = KVPagePool(num_pages=4, page_size=4)
    with pytest.raises(ValueError):
        other.load_index(path)
    # missing file is a silent cold start
    assert KVPagePool(8, 4).load_index(str(tmp_path / "nope.json")) == 0


def test_prefix_index_warm_starts_second_engine(tmp_path):
    """Cross-engine prefix cache: engine 1 publishes its prefill pages
    and saves the index at the end of run(); engine 2 (same geometry,
    handed the surviving device cache) reloads it and aliases the
    shared prefix instead of recomputing — outputs identical."""
    cfg = dataclasses.replace(get_config("gemma_2b").reduced(),
                              n_layers=2, d_model=64, d_ff=128, vocab=128,
                              n_heads=2, n_kv_heads=1, head_dim=32)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "prefix.json")
    prompt = np.random.default_rng(9).integers(0, 128, 32, dtype=np.int32)

    kw = dict(slots=2, cache_len=64, prefill_len=32, page_size=8,
              prefill_chunk=8, prefix_index_path=path)
    eng1 = ServingEngine(params, cfg, **kw)
    eng1.submit(Request(rid=0, prompt=prompt, max_tokens=8))
    out1 = eng1.run()
    assert os.path.exists(path)

    eng2 = ServingEngine(params, cfg, **kw)
    eng2.cache = eng1.cache  # device pages survive the restart
    eng2.submit(Request(rid=1, prompt=prompt, max_tokens=8))
    out2 = eng2.run()
    assert list(out2[1]) == list(out1[0])
    assert eng2.sched.pool.prefix_hit_pages > 0, \
        "second engine must alias the reloaded prefix pages"
