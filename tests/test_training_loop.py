"""End-to-end training-loop behaviour: convergence, microbatching
equivalence, checkpoint-resume exactness (fault-tolerance contract)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import train_loop
from repro.models import model as model_lib
from repro.optim.optimizer import AdamWConfig, init_opt_state
from repro.training.trainer import make_train_step


def _tiny_cfg():
    cfg = get_config("gemma_2b").reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                               vocab=128, n_heads=2, n_kv_heads=1,
                               head_dim=32)


def test_loss_decreases():
    cfg = _tiny_cfg()
    _, losses = train_loop(cfg, steps=30, batch=4, seq=32, lr=3e-3,
                           log=lambda *a: None)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatching_matches_full_batch():
    """Gradient accumulation (deferred reduction) must equal the one-shot
    gradient up to fp order."""
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(key, cfg)
    opt = init_opt_state(params)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    opt_cfg = AdamWConfig(lr=1e-3)
    p1, _, m1 = make_train_step(cfg, opt_cfg, microbatches=1)(
        params, jax.tree.map(jnp.copy, opt), batch)
    p2, _, m2 = make_train_step(cfg, opt_cfg, microbatches=4)(
        params, jax.tree.map(jnp.copy, opt), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_checkpoint_resume_is_exact(tmp_path):
    """Kill-and-restart must land on the same parameters as an unbroken
    run — checkpoint + data-state resume contract."""
    cfg = _tiny_cfg()
    kw = dict(batch=4, seq=32, lr=1e-3, log=lambda *a: None, seed=3)

    p_straight, _ = train_loop(cfg, steps=12, **kw)

    d1 = str(tmp_path / "ck")
    train_loop(cfg, steps=6, ckpt_dir=d1, ckpt_every=100, **kw)
    p_resumed, _ = train_loop(cfg, steps=12, ckpt_dir=d1, ckpt_every=100,
                              **kw)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_nan_loss_raises_for_supervisor():
    cfg = _tiny_cfg()
    with pytest.raises(FloatingPointError):
        train_loop(cfg, steps=5, batch=4, seq=32, lr=1e6,  # absurd LR → NaN
                   log=lambda *a: None)
