"""MTE CSR (paper §III-B): bit-accurate encode/decode + tss grant semantics."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # hermetic env: run properties via the local shim
    from _hypothesis_fallback import given, settings, strategies as st
import pytest

from repro.core.tile_state import MAX_DIM, SEW, TailPolicy, TileState


def test_paper_field_budget():
    """Table II: 36 bits dims + 8 bits ttypes + 12 bits rlenb + 8 reserved."""
    ts = TileState(tm=4096, tn=4096, tk=4096, rlenb=4095)
    assert ts.encode() < (1 << 56)  # everything fits below the reserved byte


def test_sew_encoding():
    assert SEW.E8.bits == 8 and SEW.E64.bits == 64
    assert SEW.from_bits(16) is SEW.E16
    assert SEW.from_dtype("float32") is SEW.E32
    with pytest.raises(ValueError):
        SEW.from_bits(12)


@settings(max_examples=200, deadline=None)
@given(
    tm=st.integers(1, MAX_DIM), tn=st.integers(1, MAX_DIM),
    tk=st.integers(1, MAX_DIM),
    sew_i=st.sampled_from(list(SEW)), sew_o=st.sampled_from(list(SEW)),
    pol_i=st.sampled_from(list(TailPolicy)),
    pol_o=st.sampled_from(list(TailPolicy)),
    rlenb=st.integers(0, 4095),
)
def test_csr_roundtrip(tm, tn, tk, sew_i, sew_o, pol_i, pol_o, rlenb):
    ts = TileState(tm=tm, tn=tn, tk=tk, sew_i=sew_i, sew_o=sew_o,
                   policy_i=pol_i, policy_o=pol_o, rlenb=rlenb)
    word = ts.encode()
    assert 0 <= word < (1 << 64)
    assert TileState.decode(word) == ts


@settings(max_examples=100, deadline=None)
@given(request=st.integers(0, 10_000), hw_max=st.integers(1, 4096))
def test_tss_grant_is_min(request, hw_max):
    """tss returns min(request, microarchitecture max) — §III-C1.
    A zero grant is returned but never written to the CSR."""
    granted, ts = TileState().tssm(request, hw_max)
    assert granted == min(request, hw_max, MAX_DIM)
    assert ts.tm == (granted if granted else 1)
    granted_n, ts = ts.tssn(request, hw_max)
    granted_k, ts = ts.tssk(request, hw_max)
    if granted_n:
        assert ts.tn == granted_n
    if granted_k:
        assert ts.tk == granted_k


def test_out_of_range_rejected():
    with pytest.raises(ValueError):
        TileState(tm=5000)
    with pytest.raises(ValueError):
        TileState(tm=0)
    with pytest.raises(ValueError):
        TileState(rlenb=5000)
