"""Serving example: continuous batching over a mixed request stream.

Demonstrates the serving engine's slot scheduler: requests of different
prompt lengths and token budgets share decode batches; finished requests
free their slot immediately and queued requests are admitted mid-flight
(per-slot decode positions — no recompilation).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = dataclasses.replace(
        get_config("qwen15_4b").reduced(), n_layers=4,
        compute_dtype="float32")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, slots=4, cache_len=128,
                           prefill_len=32)

    rng = np.random.default_rng(7)
    n_requests = 10
    for rid in range(n_requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 30),
                                dtype=np.int32),
            max_tokens=int(rng.integers(4, 12)),
            temperature=0.0 if rid % 2 == 0 else 0.8,
        ))

    t0 = time.time()
    outputs = engine.run()
    dt = time.time() - t0
    total = sum(len(v) for v in outputs.values())
    print(f"served {len(outputs)} requests / {total} tokens in {dt:.2f}s "
          f"with 4 slots (continuous batching)")
    for rid in sorted(outputs):
        print(f"  req {rid:2d}: {len(outputs[rid]):2d} tokens "
              f"{outputs[rid][:8]}{'...' if len(outputs[rid]) > 8 else ''}")
    assert len(outputs) == n_requests


if __name__ == "__main__":
    main()
