"""GEMM showcase: the paper's core result, end to end.

Reproduces the paper's motivating experiment in miniature: run the same
GEMM workloads under a *rigid* AMX-style schedule and under the MTE
geometry-agnostic schedule, comparing (a) numerics (identical), (b) the
modeled TPU efficiency of the solved schedules, and (c) the direct
convolution lowering with a fused epilogue.

Run:  PYTHONPATH=src python examples/gemm_showcase.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Epilogue, autotune, mte_gemm, plan_gemm
from repro.core.conv import conv2d_direct
from repro.core.perfmodel import model_gemm

print("=" * 72)
print("1. Geometry agnosticism: the same API, shape-adapted schedules")
print("=" * 72)
workloads = [
    ("square 2k", 2048, 2048, 2048),
    ("transformer decode GEMV", 16, 2048, 2048),
    ("small-OC conv (SqueezeNet)", 3136, 16, 64),
    ("MoE expert (qwen3)", 512, 1536, 4096),
]
print(f"{'workload':>28} | {'MTE blocks':>15} | {'MTE eff':>8} | {'rigid eff':>9}")
for name, m, n, k in workloads:
    mte = plan_gemm(m, n, k, dtype_in=jnp.bfloat16, policy="mte")
    amx = plan_gemm(m, n, k, dtype_in=jnp.bfloat16, policy="amx")
    g = mte.geometry
    print(f"{name:>28} | ({g.bm:4d},{g.bn:4d},{g.bk:4d}) | "
          f"{100 * mte.efficiency:7.1f}% | {100 * amx.efficiency:8.1f}%")

print()
print("=" * 72)
print("2. The CPU-ISA comparison (paper Fig. 7 machine model)")
print("=" * 72)
m, n, k = 3136, 64, 288  # a category-II convolution GEMM
print(f"GEMM {m}x{n}x{k}:")
for arch in ("vector1k", "sifiveint", "mte8s", "mte32s"):
    t = model_gemm(arch, m, n, k)
    print(f"  {arch:>10}: {100 * t.efficiency:5.1f}% of peak "
          f"({t.bottleneck}-bound, {t.seconds * 1e6:7.1f} us)")

print()
print("=" * 72)
print("3. Numerics: rigid and adaptive schedules agree bit-for-bit-ish")
print("=" * 72)
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((130, 70), np.float32))
b = jnp.asarray(rng.standard_normal((70, 100), np.float32))
epi = Epilogue(alpha=2.0, has_bias=True, activation="silu")
bias = jnp.asarray(rng.standard_normal(100, np.float32))
o1 = mte_gemm(a, b, bias=bias, epilogue=epi, backend="pallas", policy="mte")
o2 = mte_gemm(a, b, bias=bias, epilogue=epi, backend="pallas", policy="amx")
np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
print(f"mte vs rigid max delta: {float(jnp.max(jnp.abs(o1 - o2))):.2e}  ✓")

print()
print("=" * 72)
print("4. Direct convolution through MTE GEMMs (fused bias+ReLU epilogue)")
print("=" * 72)
x = jnp.asarray(rng.standard_normal((2, 14, 14, 64), np.float32))
w = jnp.asarray(rng.standard_normal((3, 3, 64, 128), np.float32))
cb = jnp.asarray(rng.standard_normal(128, np.float32))
y = conv2d_direct(x, w, bias=cb, pad=1,
                  epilogue=Epilogue(has_bias=True, activation="relu"))
ref = jax.lax.conv_general_dilated(
    x, w, (1, 1), [(1, 1), (1, 1)],
    dimension_numbers=("NHWC", "HWIO", "NHWC"))
ref = jnp.maximum(ref + cb, 0)
np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)
print(f"conv {x.shape} * {w.shape} -> {y.shape}  ✓ matches lax.conv")

print()
print("=" * 72)
print("5. Data-format policy: one GEMM, four SEW contracts, per-format plans")
print("=" * 72)
m, n, k = 16, 2048, 2048  # the transformer decode GEMV from section 1
print(f"decode GEMV {m}x{n}x{k}, modeled on v5e per format:")
base_us = None
for fmt in ("fp32", "bf16", "bf16acc", "int8"):
    p = plan_gemm(m, n, k, format_policy=fmt)
    g = p.geometry
    us = p.timing.seconds * 1e6
    base_us = base_us or us
    print(f"  {fmt:>8}: blocks ({g.bm:4d},{g.bn:4d},{g.bk:4d}) "
          f"SEW {g.sew_i.name}->{g.sew_o.name} -> {us:7.2f} us "
          f"({base_us / us:.2f}x fp32)")

a = jnp.asarray(rng.standard_normal((m, k), np.float32))
b = jnp.asarray(rng.standard_normal((k, n), np.float32))
o_fp32 = mte_gemm(a, b, backend="pallas")
hits0 = autotune.cache_stats().hits
o_int8 = mte_gemm(a, b, backend="pallas", format_policy="int8")
o_int8_2 = mte_gemm(a, b, backend="pallas", format_policy="int8")
assert autotune.cache_stats().hits > hits0, "expected warm plan-cache hit"
np.testing.assert_array_equal(o_int8, o_int8_2)
rel = float(jnp.max(jnp.abs(o_int8 - o_fp32)) / jnp.max(jnp.abs(o_fp32)))
assert rel < 0.05, f"int8 route strayed {rel:.3f} from fp32"
print(f"int8 quantize->int-dot->dequant vs fp32: max rel {rel:.4f} ✓ "
      f"(2nd call hit the warm plan cache)")
