"""Continuous-batching serving over the paged KV pool, end to end.

Demonstrates the PR-3 serving subsystem:

- mixed-length requests flow through the FIFO scheduler (admission by
  token budget, paged KV growth, eviction when the pool is overcommitted);
- KV pages are stored under the ``int8pt`` per-tensor-scale FormatPolicy;
- the decode step's q/k/v GEMVs run as ONE grouped GEMM, so the plan
  cache holds a single grouped signature for the whole mixed batch;
- a second engine warm-starts from the saved plan-cache JSON and the
  grouped decode signature is asserted to come back as a warm hit
  (``source == "warmstart"``) — the server starts hot.

Run:  PYTHONPATH=src python examples/serving_continuous.py
"""
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import autotune
from repro.models import model as model_lib
from repro.serving import Request, ServingEngine


def tiny_cfg():
    cfg = get_config("gemma_2b").reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                               vocab=128, n_heads=2, n_kv_heads=1,
                               head_dim=32)


def submit_mixed(engine, cfg, n_requests, seed=7):
    rng = np.random.default_rng(seed)
    for rid in range(n_requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 15),
                                dtype=np.int32),
            max_tokens=int(rng.integers(4, 10)),
        ))


def main():
    cfg = tiny_cfg()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    plan_path = os.path.join(tempfile.mkdtemp(), "serving_plans.json")

    # -- cold engine: tune, serve, persist ---------------------------------
    autotune.reset_cache()
    engine = ServingEngine(params, cfg, slots=2, cache_len=64,
                           prefill_len=16, page_size=16,
                           kv_format="int8pt", grouped_qkv=True,
                           plan_cache_path=plan_path)
    submit_mixed(engine, cfg, n_requests=6)
    t0 = time.time()
    outputs = engine.run()
    dt = time.time() - t0
    m = engine.metrics()
    total = sum(len(v) for v in outputs.values())
    print(f"cold engine: {len(outputs)} requests / {total} tokens in "
          f"{dt:.2f}s, occupancy {m['batch_occupancy']:.2f}, "
          f"kv pages int8pt ({m['num_pages']}x{m['page_size']})")
    grouped = [s for s in autotune.plan_cache()._plans if s.group > 1]
    assert len(grouped) == 1, grouped
    print(f"grouped decode signature: G={grouped[0].group} "
          f"m={grouped[0].m} n={grouped[0].n} k={grouped[0].k} "
          f"fmt={grouped[0].fmt}")
    engine.save_plan_cache()

    # -- warm engine: the grouped decode plan comes back pre-tuned ---------
    # Simulate a fresh process: drop BOTH caches.  (Within one process
    # the compiled decode-step program is memoized with its plan pinned,
    # so the plan cache would never even be consulted again; the JSON
    # warm start is what makes a *new* process compile with zero solver
    # calls.)
    autotune.reset_cache()
    from repro.graph import schedule as graph_schedule
    graph_schedule.reset_programs()
    engine2 = ServingEngine(params, cfg, slots=2, cache_len=64,
                            prefill_len=16, page_size=16,
                            kv_format="int8pt", grouped_qkv=True,
                            plan_cache_path=plan_path)
    cache = autotune.plan_cache()
    (sig,) = [s for s in cache._plans if s.group > 1]
    warm_plan = cache._plans[sig]
    assert warm_plan.source == "warmstart", warm_plan
    before = autotune.cache_stats().hits
    submit_mixed(engine2, cfg, n_requests=6)
    outputs2 = engine2.run()
    hits = autotune.cache_stats().hits - before
    assert hits > 0, "warm-started plans must be HIT, not re-solved"
    grouped2 = [s for s in cache._plans if s.group > 1]
    assert grouped2 == [sig], "decode signature must match the warm plan"
    assert sum(len(v) for v in outputs2.values()) == total
    print(f"warm engine: grouped decode plan restored from JSON "
          f"({warm_plan.describe()}), {hits} plan-cache hits — "
          f"decode starts hot")


if __name__ == "__main__":
    main()
