"""Compile a transformer block through repro.graph and count dispatches.

Runs the gemma-style block (q/k/v projections + output projection + the
swiglu MLP) twice on the kernel backend — eager per-GEMM dispatch vs
compiled ``repro.graph`` programs — and asserts the compiled block issues
*fewer plan-cache signatures* than eager while producing the same
numbers: the q/k/v siblings and the MLP's gate+up pair each collapse into
one GroupNode launch.  Also shows the dispatch-hooked tracer auditing the
eager path and the fused program's structure.

Run:  PYTHONPATH=src python examples/graph_fusion.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import autotune
from repro.graph import schedule as graph_schedule
from repro.graph import trace as graph_trace
from repro.models import attention as attn_mod
from repro.models import layers as layers_mod


def run_block(cfg, params_attn, params_mlp, x, pos):
    q, k, v = attn_mod._project_qkv(x, params_attn, cfg, pos)
    o = layers_mod.dense(q.reshape(*x.shape[:2], -1), params_attn["o"], cfg)
    y = layers_mod.mlp(x, params_mlp, cfg)
    return q, k, v, o, y


def main():
    cfg = dataclasses.replace(get_config("gemma_2b").reduced(),
                              gemm_backend="pallas", head_dim=16)
    key = jax.random.PRNGKey(0)
    params_attn = attn_mod.init_attention(key, cfg)
    params_mlp = layers_mod.init_mlp(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    pos = jnp.arange(8)[None, :].repeat(2, 0)

    results = {}
    for mode, use_graph in (("eager", False), ("compiled", True)):
        autotune.reset_cache()
        graph_schedule.reset_programs()
        c = dataclasses.replace(cfg, use_graph=use_graph)
        with graph_trace.trace_gemms() as cap:
            outs = run_block(c, params_attn, params_mlp, x, pos)
        sigs = len(autotune.plan_cache())
        results[mode] = (sigs, cap.n_dispatches, outs)
        print(f"{mode:>9}: {cap.n_dispatches} kernel dispatches, "
              f"{sigs} plan-cache signatures")

    sig_e, disp_e, outs_e = results["eager"]
    sig_c, disp_c, outs_c = results["compiled"]
    assert sig_c < sig_e, "compiled must issue fewer signatures than eager"
    assert disp_c < disp_e
    for a, b in zip(outs_c, outs_e):
        err = float(jnp.max(jnp.abs(a - b)) / (1e-9 + jnp.max(jnp.abs(b))))
        assert err < 1e-4, err
    print(f"fusion win: {disp_e} -> {disp_c} dispatches "
          f"({100 * (1 - disp_c / disp_e):.0f}% fewer), "
          f"{sig_e} -> {sig_c} signatures; outputs match")

    # Peek at the compiled programs.
    for prog in graph_schedule.compiled_programs():
        print()
        print(prog.describe())

    # The tracer also audits *any* eager pipeline: here, the three
    # projections of a decode step before grouping.
    np_rng = np.random.default_rng(0)
    a = jnp.asarray(np_rng.standard_normal((4, cfg.d_model)), jnp.float32)
    from repro.kernels import ops
    with graph_trace.trace_gemms() as cap:
        for name in ("q", "k", "v"):
            ops.mte_gemm(a, params_attn[name]["w"])
    g = cap.graph()
    prog = graph_schedule.compile_graph(g)
    print()
    print(f"traced decode projections: {cap.n_dispatches} eager dispatches "
          f"-> {prog.n_dispatches} compiled (grouped)")
    assert prog.n_dispatches < cap.n_dispatches


if __name__ == "__main__":
    main()
