"""Speculative decoding through the graph IR, end to end.

Demonstrates the PR-7 serving tentpole:

- a weight-shared draft (the first scan group of the target, zero extra
  parameter memory) proposes ``k-1`` tokens per decode step;
- the target scores the whole window in ONE ``verify_chunk`` call whose
  GEMMs carry ``M = slots*k`` rows — the M=1 decode GEMV becomes the
  GEMM shape family the paper's flexible tiles are built for;
- greedy outputs are asserted **bit-identical** to vanilla decode:
  rejected drafts rewind page-table positions only, they never corrupt
  the sequence;
- the merged draft+verify GEMM program (draft grouped q/k/v + verify
  grouped q/k/v + verify unembed, ONE ``repro.graph`` program) is
  compiled once: a second engine with the same geometry is asserted to
  get it as a warm program-cache hit, not a recompile.

Run:  PYTHONPATH=src python examples/speculative_decoding.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.graph import schedule as graph_schedule
from repro.models import model as model_lib
from repro.serving import Request, ServingEngine

SPEC_K = 4


def tiny_cfg():
    cfg = get_config("gemma2_27b").reduced()
    return dataclasses.replace(cfg, n_layers=4, d_model=64, d_ff=128,
                               vocab=128, n_heads=2, n_kv_heads=1,
                               head_dim=32)


def submit_shared_prefix(engine, cfg, n_requests, seed=11):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, 24, dtype=np.int32)
    for rid in range(n_requests):
        tail = rng.integers(0, cfg.vocab, 6 + rid, dtype=np.int32)
        engine.submit(Request(rid=rid,
                              prompt=np.concatenate([shared, tail]),
                              max_tokens=16))


def run_engine(params, cfg, spec_k):
    engine = ServingEngine(params, cfg, slots=2, cache_len=128,
                           prefill_len=32, page_size=16,
                           spec_k=spec_k, debug_audit=True)
    submit_shared_prefix(engine, cfg, n_requests=4)
    t0 = time.time()
    outputs = engine.run()
    dt = time.time() - t0
    return {rid: list(r) for rid, r in outputs.items()}, engine, dt


def main():
    cfg = tiny_cfg()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)

    # -- vanilla baseline ---------------------------------------------------
    vanilla, _, dt_v = run_engine(params, cfg, spec_k=0)
    total = sum(len(v) for v in vanilla.values())
    print(f"vanilla decode: {total} tokens in {dt_v:.2f}s")

    # -- speculative: same tokens, fewer target steps -----------------------
    spec, engine, dt_s = run_engine(params, cfg, spec_k=SPEC_K)
    assert spec == vanilla, "greedy speculative output must be bit-identical"
    m = engine.metrics()
    print(f"speculative k={SPEC_K}: {total} tokens in {dt_s:.2f}s, "
          f"{m['spec_steps']} verify steps, "
          f"accepted/step {m['accepted_per_step']:.2f}, "
          f"acceptance rate {m['acceptance_rate']:.2f} — outputs "
          f"bit-identical to vanilla")
    assert m["spec_steps"] > 0 and m["spec_emitted"] > 0

    # -- the merged draft+verify program is a warm hit ----------------------
    # The engine compiled its speculative GEMM pipeline (draft grouped
    # q/k/v + verify grouped q/k/v at M = slots*k + verify unembed) as
    # ONE repro.graph program at construction.  A second engine with the
    # same geometry must get that program back from the cache: hits grow,
    # compiles stay flat.
    before = graph_schedule.program_stats()
    _, engine2, _ = run_engine(params, cfg, spec_k=SPEC_K)
    after = graph_schedule.program_stats()
    assert after["hits"] > before["hits"], (before, after)
    assert after["compiles"] == before["compiles"], (before, after)
    assert engine2._spec_program is engine._spec_program
    print(f"merged draft+verify program: warm cache hit on the second "
          f"engine (compiles {after['compiles']}, hits "
          f"{after['hits']} > {before['hits']})")


if __name__ == "__main__":
    main()
