"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full production stack on CPU: config → sharded init → jit'd
train step (donated buffers) → synthetic data pipeline → async
checkpointing → watchdog → resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train_loop
from repro.models import model as model_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/mte_train_lm")
    args = ap.parse_args()

    # ~100M params: a gemma-family config scaled to laptop size.
    cfg = dataclasses.replace(
        get_config("gemma_2b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=1, head_dim=64,
        d_ff=2048, vocab=32768, compute_dtype="float32", remat="none")

    import jax
    n = model_lib.param_count(
        jax.eval_shape(lambda: model_lib.init_params(
            jax.random.PRNGKey(0), cfg)))
    print(f"training {cfg.name} variant: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} × seq {args.seq}")

    params, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq, lr=1e-3,
        ckpt_dir=args.ckpt_dir, ckpt_every=100)
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} "
          f"(Δ {losses[0] - losses[-1]:+.3f})")


if __name__ == "__main__":
    main()
