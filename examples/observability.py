"""Observability walkthrough: profile a serving wave, audit the planner,
watch SLOs, and export the whole picture.

Runs a small continuous-batching wave with every PR-9 collector enabled,
then:

1. joins measured dispatch time against the analytic performance model
   (the modeled-vs-measured *calibration table*, per shape class /
   format / plan source);
2. audits the plan cache's hottest grants against their analytic
   runner-up schedules (the *plan-regret audit*) and feeds winning
   measurements back via ``PlanCache.recalibrate``;
3. evaluates declarative SLOs (TTFT p99, error rate, KV headroom) as
   multi-window burn rates every engine step;
4. renders the metrics registry as Prometheus text and the whole stack
   as one schema-validated ``health()`` JSON snapshot — the same
   artifacts ``repro.launch.serve --prom/--status-json`` writes.

Run:  PYTHONPATH=src python examples/observability.py
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import autotune, dispatch
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine
from repro.telemetry import gemm_account
from repro.telemetry.export import (health, render_prometheus,
                                    validate_health)
from repro.telemetry.profiler import DispatchProfiler
from repro.telemetry.registry import registry
from repro.telemetry.slo import SloMonitor, default_slos

cfg = get_config("gemma_2b").reduced()
cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                          vocab=128, n_heads=2, n_kv_heads=1, head_dim=32)
params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

print("=" * 72)
print("1. A serving wave with the full observability stack enabled")
print("=" * 72)
monitor = SloMonitor(default_slos(ttft_p99_s=300.0, error_rate=0.5,
                                  min_free_page_frac=0.0))
acct = gemm_account.install(gemm_account.GemmAccountant())
engine = ServingEngine(params, cfg, slots=2, cache_len=64, prefill_len=16,
                       slo_monitor=monitor)
for rid in range(4):
    prompt = rng.integers(0, cfg.vocab, size=6 + 3 * rid, dtype=np.int32)
    engine.submit(Request(rid=rid, prompt=prompt, max_tokens=6))
outputs = engine.run()
gemm_account.uninstall()
print(f"served {len(outputs)} requests in {engine.step_idx} steps, "
      f"{len(acct.records)} GEMM dispatch records, "
      f"{monitor.evaluations} SLO evaluations")

print()
print("=" * 72)
print("2. Modeled vs measured: the calibration table")
print("=" * 72)
prof = DispatchProfiler(acct, iters=1)
n = prof.sample()
print(f"timed {n} hot dispatch signatures (under accounting suppression)")
print(prof.format_calibration_table())
installed = prof.install_calibration()
print(f"installed {installed} per-(shape_class, fmt) correction factors "
      f"into the perf model")

print()
print("=" * 72)
print("3. Plan-regret audit: did the planner grant the right schedule?")
print("=" * 72)
# The CPU serving wave ran on the xla backend, so the plan cache is
# empty — drive a few planner-granted pallas dispatches to give the
# audit material (on a TPU serving host these come from the wave itself).
with gemm_account.account_gemms() as audit_acct:
    for m, n, k in ((64, 48, 64), (8, 128, 64)):
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        dispatch.mte_gemm(a, b, backend="pallas").block_until_ready()
audit_prof = DispatchProfiler(audit_acct, iters=1)
audit_prof.sample()
for e in audit_prof.regret_audit(top_k=3, recalibrate=True):
    verdict = "REGRET" if e["flagged"] else "ok"
    print(f"  [{verdict:>6}] {e['signature']}: granted "
          f"{e['granted_route']}/{e['granted_source']} "
          f"{e['granted_s'] * 1e6:8.1f} us vs runner-up "
          f"{e['runner_route']} {e['runner_s'] * 1e6:8.1f} us "
          f"(regret {e['regret']:+.1%})")
stats = autotune.cache_stats()
print(f"plan cache: {stats.hits} hits, {stats.measured} measured grants")

print()
print("=" * 72)
print("4. SLO verdicts (multi-window burn rates)")
print("=" * 72)
print(monitor.last_report.format_report())

print()
print("=" * 72)
print("5. Exposition: Prometheus text + the health() JSON snapshot")
print("=" * 72)
prom = render_prometheus()
lines = prom.strip().splitlines()
print("\n".join(lines[:8]))
print(f"... ({len(lines)} lines, "
      f"{sum(1 for l in lines if l.startswith('# TYPE'))} metrics)")
doc = health(engine=engine, profiler=prof,
             slo_report=monitor.last_report)
errs = validate_health(doc)
assert not errs, errs
print()
print(f"health snapshot valid (version {doc['version']}): "
      f"{len(doc['registry'])} metrics, kv {doc['kv']['free_pages']}/"
      f"{doc['kv']['num_pages']} pages free, "
      f"{len(doc['calibration']['rows'])} calibration rows, "
      f"slo ok={doc['slo']['ok']}")
print(json.dumps({k: doc[k] for k in ("version", "kv", "scheduler")},
                 indent=2, sort_keys=True))
assert registry().get("kv.num_pages") is not None
print("done ✓")
