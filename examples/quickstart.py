"""Quickstart: the MTE GEMM public API in 60 seconds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Epilogue, mte_gemm, plan_gemm
from repro.core.tile_state import SEW, TileState
from repro.core.geometry import PROFILES, max_tile_dims

# ---------------------------------------------------------------------------
# 1. The paper's architectural state: tile shapes live in one 64-bit CSR and
#    the hardware *grants* geometry from VLEN/RLEN/SEW (Formula 2/3).
# ---------------------------------------------------------------------------
tile = max_tile_dims(PROFILES["mte32s"], SEW.E32)
print(f"Formula 2 (VLEN 8192, RLEN 512, fp32): max tile = {tile.mnk}")
tile_mixed = max_tile_dims(PROFILES["mte32s"], SEW.E16, SEW.E32)
print(f"Formula 3 (bf16→f32, B transposed):    max tile = {tile_mixed.mnk}")

csr = TileState(tm=16, tn=16, tk=16, sew_i=SEW.E16, sew_o=SEW.E32)
print(f"CSR word: 0x{csr.encode():016x}  (decodes back: "
      f"{TileState.decode(csr.encode()) == csr})")

# ---------------------------------------------------------------------------
# 2. The TPU adaptation: the geometry solver picks Pallas block shapes from
#    the problem + hardware constants — never hard-coded.
# ---------------------------------------------------------------------------
for (m, n, k) in [(4096, 4096, 4096), (16, 2048, 512), (3136, 32, 288)]:
    plan = plan_gemm(m, n, k, dtype_in=jnp.bfloat16)
    g = plan.geometry
    print(f"GEMM {m}x{n}x{k}: blocks ({g.bm},{g.bn},{g.bk}) "
          f"transposed_b={g.transposed_b} → modeled "
          f"{100 * plan.efficiency:.0f}% of v5e peak "
          f"({plan.timing.bottleneck}-bound)")

# ---------------------------------------------------------------------------
# 3. Run a GEMM with a fused BLAS epilogue (the matrix↔vector interplay):
#    act(alpha·AB + beta·C + bias) in one kernel pass.
# ---------------------------------------------------------------------------
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((100, 70), np.float32))
b = jnp.asarray(rng.standard_normal((70, 50), np.float32))
c = jnp.asarray(rng.standard_normal((100, 50), np.float32))
bias = jnp.asarray(rng.standard_normal(50, np.float32))
epi = Epilogue(alpha=0.5, beta=1.0, has_bias=True, activation="gelu")

out_pallas = mte_gemm(a, b, c, bias, epilogue=epi, backend="pallas")
out_ref = mte_gemm(a, b, c, bias, epilogue=epi, backend="reference")
np.testing.assert_allclose(out_pallas, out_ref, rtol=2e-5, atol=2e-5)
print(f"\nfused-epilogue GEMM: pallas == reference ✓ "
      f"(max abs {float(jnp.max(jnp.abs(out_pallas - out_ref))):.2e})")

# ---------------------------------------------------------------------------
# 4. Data-format policies: the SEW field as an API.  The same GEMM runs
#    fp32 / bf16 / int8-with-scales by naming a policy — quantization,
#    accumulator width and the per-format cached plan are all derived.
# ---------------------------------------------------------------------------
from repro.core import FORMATS
from repro.core import autotune

tile_int8 = max_tile_dims(PROFILES["mte32s"], SEW.E8, SEW.E32)
print(f"\nFormula 3 (int8→i32, B transposed):    max tile = {tile_int8.mnk}")
for name in ("fp32", "bf16", "bf16acc", "int8"):
    print(f"  {FORMATS[name].describe()}")

out_fp32 = mte_gemm(a, b, c, bias, epilogue=epi, backend="pallas")
hits0 = autotune.cache_stats().hits
out_int8 = mte_gemm(a, b, c, bias, epilogue=epi, backend="pallas",
                    format_policy="int8")
out_int8_again = mte_gemm(a, b, c, bias, epilogue=epi, backend="pallas",
                          format_policy="int8")
# Same (shape, format) twice ⇒ the second call is a warm plan-cache hit.
assert autotune.cache_stats().hits > hits0, "expected a warm plan-cache hit"
np.testing.assert_array_equal(out_int8, out_int8_again)
rel = float(jnp.max(jnp.abs(out_int8 - out_fp32))
            / jnp.max(jnp.abs(out_fp32)))
assert rel < 0.05, f"int8 route strayed {rel:.3f} from the fp32 oracle"
print(f"int8 GEMM: warm cache hit on 2nd call ✓, "
      f"max rel delta vs fp32 {rel:.4f} (per-channel scales)")

# ---------------------------------------------------------------------------
# 5. A model from the zoo, one forward pass.
# ---------------------------------------------------------------------------
from repro.configs import get_config
from repro.models import model as M

cfg = get_config("gemma_2b").reduced()
params = M.init_params(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                      cfg.vocab)}
logits, _ = M.forward(params, batch, cfg)
print(f"gemma_2b (reduced) forward: logits {logits.shape}, "
      f"loss {float(M.loss_fn(params, batch, cfg)[0]):.3f}")
